"""Indexed decode store (ISSUE 3): container format round trips, range
decode equivalence against the sequential decoder, parse locality, and the
serving read path.

The load-bearing acceptance test is range equivalence: for every golden
mode x D case, ``decode_range(store, i, j)`` must be BYTE-identical to
``decode_stream(stream)[i*B : j*B]`` -- including std-mode hit
permutations, which are keyed on the global block position exactly so this
holds.  The locality test pins, via ``segment_walk_count``, that a small
range of a many-segment container walks only the covering segments.
"""
import os

import numpy as np
import pytest

from conftest import GOLDEN_CASES, golden_codec_kwargs, golden_signal
from repro.core import IdealemCodec, StreamFormatError
from repro.core import stream as stream_mod
from repro.core.stream import decode_stream
from repro.serve import DecompressionService, FlushPolicy
from repro.store import (Container, ContainerFormatError, ContainerWriter,
                         decode_channels, decode_range, decode_ranges, pack)
from test_golden_corpus import _golden_bytes

FEED = 100  # session chunk size (samples) used to build multi-segment streams


def _session_stream(name, feed=FEED):
    codec = IdealemCodec(**golden_codec_kwargs(name))
    x = golden_signal(name)
    s = codec.session()
    segs = [s.feed(x[lo:lo + feed]) for lo in range(0, len(x), feed)]
    segs.append(s.finish())
    return b"".join(segs)


def _all_ranges(nb):
    return [(i, j) for i in range(nb) for j in range(i + 1, nb + 1)]


# ----------------------------------------------- range-decode equivalence
@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_all_ranges_equal_full_decode_oneshot(name):
    """Every (i, j) over every golden one-shot stream: the random-access
    read must be byte-identical to the sequential decode's slice."""
    blob = _golden_bytes(name)
    y = decode_stream(blob)
    store = Container(pack(blob))
    B = store.header_of(0).block_size
    nb = store.total_blocks(0)
    for i, j in _all_ranges(nb):
        np.testing.assert_array_equal(
            decode_range(store, i, j), y[i * B:j * B],
            err_msg=f"{name} blocks [{i}, {j})")


@pytest.mark.parametrize("name", sorted(GOLDEN_CASES))
def test_all_ranges_equal_full_decode_multisegment(name):
    """Same, over the chunked-session (FLAG_MORE/FLAG_CONT) form of each
    golden signal: ranges that start inside continuation segments source
    carried dictionary entries from the index snapshots."""
    blob = _session_stream(name)
    y = decode_stream(blob)
    np.testing.assert_array_equal(y, decode_stream(_golden_bytes(name)))
    store = Container(pack(blob))
    assert store.n_chunks > 3  # must actually be multi-segment
    B = store.header_of(0).block_size
    nb = store.total_blocks(0)
    for i, j in _all_ranges(nb):
        np.testing.assert_array_equal(
            decode_range(store, i, j), y[i * B:j * B],
            err_msg=f"{name} blocks [{i}, {j})")


def test_decode_ranges_batched_equals_loop():
    blob = _session_stream("std_D32")
    store = Container(pack(blob))
    nb = store.total_blocks(0)
    reqs = [(0, i, j) for i, j in [(0, nb), (3, 5), (nb - 1, nb), (7, 29)]]
    batched = decode_ranges(store, reqs)
    for (_, i, j), got in zip(reqs, batched):
        np.testing.assert_array_equal(got, decode_range(store, i, j))


def test_decode_channels_equals_stream_decode():
    rng = np.random.default_rng(0)
    C = 3
    chans = np.stack([rng.normal(c, 1.0, size=16 * 50 + 4) for c in range(C)])
    codec = IdealemCodec(mode="std", block_size=16, num_dict=8, alpha=0.05,
                         rel_tol=0.5, backend="numpy")
    s = codec.session(channels=C)
    parts = [s.feed(chans[:, :300]), s.feed(chans[:, 300:]), s.finish()]
    per_chan = {c: b"".join(p[c] for p in parts) for c in range(C)}
    store = Container(pack(per_chan))
    assert store.channels == [0, 1, 2]
    out = decode_channels(store)
    for c in range(C):
        np.testing.assert_array_equal(out[c], decode_stream(per_chan[c]))
        np.testing.assert_array_equal(store.tail(c), chans[c][-4:])


def test_empty_and_tail_only_streams_pack():
    """Zero-block streams (empty / shorter than one block) still pack and
    read back: the container must not choke on 0-block chunks."""
    codec = IdealemCodec(mode="std", block_size=16, num_dict=4,
                         backend="numpy")
    for x in [np.zeros(0), np.arange(5, dtype=np.float64)]:
        store = Container(pack(codec.encode(x)))
        assert store.total_blocks(0) == 0
        np.testing.assert_array_equal(decode_channels(store)[0], x)
        with pytest.raises(IndexError):
            decode_range(store, 0, 1)


def test_out_of_range_requests_raise():
    store = Container(pack(_golden_bytes("std_D32")))
    nb = store.total_blocks(0)
    for bad in [(-1, 2), (0, nb + 1), (5, 5), (7, 3)]:
        with pytest.raises(IndexError):
            decode_range(store, *bad)
    with pytest.raises(KeyError):
        decode_range(store, 0, 1, channel=9)


# --------------------------------------------------------- parse locality
def test_small_range_walks_only_covering_segments():
    """Acceptance criterion: decoding a small range of a large multi-segment
    container parses only the segments covering that range."""
    blob = _session_stream("std_D32", feed=4 * 16)  # 4-block segments
    store = Container(pack(blob))
    assert store.n_chunks >= 10
    y = decode_stream(blob)

    before = stream_mod.segment_walk_count()
    got = decode_range(store, 17, 19)  # inside one 4-block segment
    assert stream_mod.segment_walk_count() - before == 1
    np.testing.assert_array_equal(got, y[17 * 16:19 * 16])

    before = stream_mod.segment_walk_count()
    decode_range(store, 18, 22)  # straddles a segment boundary
    assert stream_mod.segment_walk_count() - before == 2

    before = stream_mod.segment_walk_count()
    decode_range(store, 0, store.total_blocks(0))
    full_walks = stream_mod.segment_walk_count() - before
    assert full_walks >= 10  # the full read really does walk everything


def test_seek_work_independent_of_prefix_length():
    """The indexed read of the LAST block must not get slower (in walked
    segments -- the work unit) as the stream grows."""
    for feed in [64, 16 * 40 + 5]:
        blob = _session_stream("delta_D1_vr", feed=feed)
        store = Container(pack(blob))
        nb = store.total_blocks(0)
        before = stream_mod.segment_walk_count()
        decode_range(store, nb - 1, nb)
        assert stream_mod.segment_walk_count() - before == 1


# ------------------------------------------------------- container format
def test_container_rejects_corruption():
    good = pack(_golden_bytes("std_D32"))
    Container(good)  # sanity
    with pytest.raises(ContainerFormatError, match="magic"):
        Container(b"NOTAPACK" + good[8:])
    with pytest.raises(ContainerFormatError, match="footer"):
        Container(good[:-8])
    with pytest.raises(ContainerFormatError, match="CRC"):
        flipped = bytearray(good)
        flipped[-30] ^= 0xFF  # inside the index
        Container(bytes(flipped))
    with pytest.raises(ContainerFormatError):
        Container(good[: len(good) // 2])
    with pytest.raises(ContainerFormatError):
        Container(b"")


def test_container_rejects_out_of_region_snapshot():
    """Snapshot offsets feed the payload gather directly, so a forged one
    must be caught at open time, not surface as a numpy IndexError (or a
    silent read of index bytes as samples) during decode."""
    import struct
    import zlib
    good = pack(_session_stream("std_D32"))
    store = Container(good)
    foot = struct.Struct("<8sQII")
    magic, idx_off, idx_len, _ = foot.unpack_from(good, len(good) - foot.size)
    index = bytearray(good[idx_off:idx_off + idx_len])
    # last 8 index bytes = a snapshot offset (final CONT chunk has fill>0)
    assert store.snapshot(store.n_chunks - 1).size > 0
    struct.pack_into("<q", index, idx_len - 8, 10 ** 9)
    forged = (good[:idx_off] + bytes(index)
              + foot.pack(magic, idx_off, idx_len, zlib.crc32(bytes(index))))
    with pytest.raises(ContainerFormatError, match="snapshot offset"):
        Container(forged)


def test_container_is_byte_verbatim():
    """Chunks store segments untouched: reassembling a channel reproduces
    the original stream exactly."""
    for name in sorted(GOLDEN_CASES):
        blob = _session_stream(name)
        store = Container(pack(blob))
        assert store.stream_bytes(0) == blob


def test_writer_rejects_malformed_appends():
    seg_stream = _session_stream("std_D32")
    segs, _, _, _ = stream_mod._walk_all(memoryview(seg_stream))
    seg_bytes = [seg_stream[s.start:s.end] for s in segs]

    w = ContainerWriter()
    with pytest.raises(StreamFormatError, match="FLAG_CONT"):
        w.append(seg_bytes[1])  # a continuation segment cannot open a channel

    w = ContainerWriter()
    w.append(seg_bytes[0])
    with pytest.raises(StreamFormatError, match="FLAG_CONT"):
        w.append(seg_bytes[0])  # restarting mid-channel is rejected

    w = ContainerWriter()
    w.append(seg_stream)  # whole chain: final segment closes the channel
    with pytest.raises(StreamFormatError, match="finished"):
        w.append(seg_bytes[1])

    w = ContainerWriter()
    w.append(seg_bytes[0])
    # same channel, different codec parameters -- must not be accepted.
    # max_count (header byte 9) is ignored by the D>=2 walk, so the segment
    # stays structurally valid and only the parameter check can object.
    mutated = bytearray(seg_bytes[1])
    mutated[9] ^= 0x0F
    with pytest.raises(StreamFormatError, match="parameters"):
        w.append(bytes(mutated))


def test_writer_file_roundtrip_and_reopen(tmp_path):
    blob = _session_stream("residual_D32_vr")
    segs, _, _, _ = stream_mod._walk_all(memoryview(blob))
    seg_bytes = [blob[s.start:s.end] for s in segs]
    path = os.path.join(tmp_path, "t.idlmc")

    w = ContainerWriter(path)
    for sb in seg_bytes[: len(seg_bytes) // 2]:
        w.append(sb)
    assert w.finalize() is None
    w2 = ContainerWriter.reopen(path)
    for sb in seg_bytes[len(seg_bytes) // 2:]:
        w2.append(sb)
    w2.finalize()

    store = Container.open(path)
    assert store.stream_bytes(0) == blob
    y = decode_stream(blob)
    nb = store.total_blocks(0)
    for i, j in [(0, nb), (nb // 2 - 1, nb // 2 + 2), (nb - 1, nb)]:
        np.testing.assert_array_equal(decode_range(store, i, j),
                                      y[i * 16:j * 16])


def test_session_and_service_container_output():
    """encode -> store -> range-decode end to end through the public API."""
    from repro.serve import CompressionService
    rng = np.random.default_rng(3)
    x = np.concatenate([rng.normal(i % 4, 1.0, size=256) for i in range(20)])
    kwargs = dict(mode="std", block_size=16, num_dict=16, alpha=0.05,
                  rel_tol=0.5, backend="numpy")
    codec = IdealemCodec(**kwargs)
    y = codec.decode(codec.encode(x))

    s = codec.session(container=True)
    for lo in range(0, len(x), 700):
        s.feed(x[lo:lo + 700])
    store = Container(s.finish())
    np.testing.assert_array_equal(decode_channels(store)[0], y)

    svc = CompressionService(**kwargs)
    svc.open_stream("pmu", container=True)
    for lo in range(0, len(x), 700):
        svc.feed("pmu", x[lo:lo + 700])
    store2 = Container(svc.close_stream("pmu"))
    nb = store2.total_blocks(0)
    np.testing.assert_array_equal(decode_range(store2, 5, 9), y[5 * 16:9 * 16])
    np.testing.assert_array_equal(decode_channels(store2)[0], y)
    assert nb == len(x) // 16


# --------------------------------------------------------- mmap-backed open
def test_mmap_open_equals_in_memory(tmp_path):
    """``Container.open(path, mmap=True)`` must behave identically to the
    bytes-backed open -- and hand out zero-copy memoryview chunks."""
    blob = _session_stream("std_D32")
    path = os.path.join(tmp_path, "m.idlmc")
    pack(blob, path=path)
    y = decode_stream(blob)
    with Container.open(path, mmap=True) as store:
        assert store._mmap is not None
        cv = store.chunk_bytes(0)
        assert isinstance(cv, memoryview)
        assert store.stream_bytes(0) == blob
        nb = store.total_blocks(0)
        for i, j in [(0, nb), (5, 9), (nb - 1, nb)]:
            np.testing.assert_array_equal(decode_range(store, i, j),
                                          y[i * 16:j * 16])
        np.testing.assert_array_equal(decode_channels(store)[0], y)
        # identity token: same file generation as a bytes-backed open
        assert store.cache_token == Container.open(path).cache_token
        del cv  # exported view must be dropped before close()
    assert store._mmap is None  # context manager closed the map
    store2 = Container.open(path)  # plain open still works after close
    assert store2.total_blocks(0) == nb


def test_mmap_reopen_changes_generation(tmp_path):
    """Appending to a file is a new generation: parsed-chunk caches keyed
    on (path, generation) must not serve stale walks."""
    blob = _session_stream("residual_D32_vr")
    segs, _, _, _ = stream_mod._walk_all(memoryview(blob))
    seg_bytes = [blob[s.start:s.end] for s in segs]
    path = os.path.join(tmp_path, "g.idlmc")
    w = ContainerWriter(path)
    for sb in seg_bytes[:-1]:
        w.append(sb)
    w.finalize()
    tok1 = Container.open(path).cache_token
    w2 = ContainerWriter.reopen(path)
    w2.append(seg_bytes[-1])
    w2.finalize()
    tok2 = Container.open(path).cache_token
    assert tok1 != tok2 and tok1[0] == tok2[0]


def test_store_tool_bigcheck_smoke(tmp_path):
    """The >RAM-budget synthetic-archive exercise end to end, size-capped
    for CI (`make store-check` runs the bigger sweep)."""
    import importlib
    import sys
    scripts = os.path.join(os.path.dirname(__file__), "..", "scripts")
    sys.path.insert(0, scripts)
    try:
        store_tool = importlib.import_module("store_tool")
        out = os.path.join(tmp_path, "big.idlmc")
        rc = store_tool.main(["bigcheck", "--mb", "1", "--channel-blocks",
                              "256", "--mmap", "--out", out])
        assert rc == 0
        assert os.path.getsize(out) > 1e6
        rc = store_tool.main(["inspect", out, "--mmap"])
        assert rc == 0
    finally:
        sys.path.remove(scripts)


# ----------------------------------------------- snapshot deltas (index v2)
def test_snapshot_delta_index_shrinks():
    """High-D channel cut into many tiny segments: the delta-form index
    must store far fewer snapshot entries than one full snapshot per chunk
    (the ISSUE 4 regression bound), while staying range-exact."""
    B, D, warm, cruise = 8, 48, 48, 200
    rng = np.random.default_rng(7)
    # warm-up fills all D slots (distinct levels), then a long all-hit
    # cruise: every cruise chunk enters with a full 48-deep dictionary
    x = np.concatenate([
        (np.arange(warm) * 50.0).repeat(B) + rng.normal(0, 0.1, warm * B),
        (rng.integers(0, D, size=cruise) * 50.0).repeat(B)
        + rng.normal(0, 0.1, cruise * B),
    ])
    codec = IdealemCodec(mode="std", block_size=B, num_dict=D, alpha=0.05,
                         rel_tol=0.5, backend="numpy")
    s = codec.session()
    segs = [s.feed(x[lo:lo + B]) for lo in range(0, len(x), B)]  # 1-block segs
    segs.append(s.finish())
    blob = b"".join(segs)
    store = Container(pack(blob))
    assert store.n_chunks > 200
    info = store.describe()
    full, delta = info["snapshot_entries"], info["snapshot_delta_entries"]
    assert full > D * cruise // 2  # the v1 full-snapshot form pays this
    # a 1-block segment changes at most one slot, so deltas ~ chunk count
    assert delta <= store.n_chunks
    assert delta < full / 20
    y = decode_stream(blob)
    nb = store.total_blocks(0)
    for i, j in [(0, nb), (nb // 2, nb // 2 + 1), (nb - 1, nb), (3, 17)]:
        np.testing.assert_array_equal(decode_range(store, i, j),
                                      y[i * B:j * B])


def test_snapshot_delta_rejects_bad_slot():
    """A forged delta slot outside the chunk's fill range must fail at
    open time, not corrupt the reassembled snapshots."""
    import struct
    import zlib
    good = pack(_session_stream("std_D32"))
    store = Container(good)
    foot = struct.Struct("<8sQII")
    magic, idx_off, idx_len, _ = foot.unpack_from(good, len(good) - foot.size)
    index = bytearray(good[idx_off:idx_off + idx_len])
    n_delta = int(store._cols["snap_delta"].sum())
    assert n_delta > 0
    # slots blob sits between the columns and the 8-byte offsets blob
    slot0_off = idx_len - 8 * n_delta - n_delta
    index[slot0_off] = 200  # slot 200 >> any fill counter in this stream
    forged = (good[:idx_off] + bytes(index)
              + foot.pack(magic, idx_off, idx_len, zlib.crc32(bytes(index))))
    with pytest.raises(ContainerFormatError, match="delta slot"):
        Container(forged)


# --------------------------------------------------- parse-cache identity
def test_parse_cache_shared_across_container_instances(tmp_path):
    """Two attaches of the same file -- different Container instances --
    must share parsed-chunk cache entries (keyed on (path, generation),
    not object identity), and detach of one must not evict the other's."""
    blob = _session_stream("std_D32", feed=4 * 16)
    path = os.path.join(tmp_path, "c.idlmc")
    pack(blob, path=path)
    svc = DecompressionService(cache_blocks=10 ** 9)
    svc.attach("a", Container.open(path))
    svc.attach("b", Container.open(path))  # distinct instance, same file
    svc.read("a", 17, 19)
    misses0 = svc.stats["cache_misses"]
    svc.read("b", 17, 19)  # same chunks via the other attach: cache hits
    assert svc.stats["cache_misses"] == misses0
    assert svc.stats["cache_hits"] >= 1
    svc.detach("a")  # shared-token entries survive while "b" lives
    svc.read("b", 17, 19)
    assert svc.stats["cache_misses"] == misses0
    svc.detach("b")
    assert svc._cached_blocks == 0  # last holder gone: entries evicted


# ------------------------------------------------------- serving read path
def test_decompression_service_reads_and_batches():
    blob = _session_stream("std_D32")
    y = decode_stream(blob)
    svc = DecompressionService(policy=FlushPolicy(max_batch_streams=3))
    svc.attach("g", pack(blob))
    with pytest.raises(KeyError):
        svc.attach("g", pack(blob))

    np.testing.assert_array_equal(svc.read("g", 2, 6), y[2 * 16:6 * 16])
    assert svc.submit("r1", "g", 0, 4) is None
    assert svc.submit("r2", "g", 10, 12) is None
    with pytest.raises(KeyError):
        svc.submit("r1", "g", 0, 1)  # duplicate pending id
    ans = svc.submit("r3", "g", 39, 40)  # third request trips the policy
    assert set(ans) == {"r1", "r2", "r3"}
    np.testing.assert_array_equal(ans["r1"], y[: 4 * 16])
    np.testing.assert_array_equal(ans["r3"], y[39 * 16:40 * 16])
    assert svc.stats["flushes"] == 1

    np.testing.assert_array_equal(svc.read_channels("g")[0], y)
    with pytest.raises(IndexError):
        svc.submit("r4", "g", 0, 10 ** 6)
    svc.detach("g")
    with pytest.raises(KeyError):
        svc.read("g", 0, 1)


def test_detach_drops_pending_accounting():
    """Detaching a store with staged requests must also drop their block
    count and age, or survivors inherit flush pressure from dead work."""
    blob = _session_stream("std_D32")
    y = decode_stream(blob)
    t = [0.0]
    svc = DecompressionService(
        policy=FlushPolicy(max_batch_blocks=50, max_age_s=10.0),
        clock=lambda: t[0])
    svc.attach("a", pack(blob))
    svc.attach("b", pack(blob))
    assert svc.submit("r1", "a", 0, 40) is None
    svc.detach("a")
    t[0] = 9.0
    # 20 pending blocks < 50 and the oldest LIVE request is 0s old: neither
    # threshold may trip on stale accounting from the detached store
    assert svc.submit("r2", "b", 0, 20) is None
    assert svc.poll() is None
    t[0] = 19.5
    out = svc.poll()  # r2 is now 10.5s old: deadline fires on its own age
    assert set(out) == {"r2"}
    np.testing.assert_array_equal(out["r2"], y[: 20 * 16])
    # the dropped request is reported, not silently forgotten -- and a
    # later flush must not erase the record before the caller reads it
    assert isinstance(svc.last_errors["r1"], KeyError)
    assert svc.stats["failed_requests"] == 1


def test_decompression_service_lru_cache():
    blob = _session_stream("std_D32", feed=4 * 16)
    store = Container(pack(blob))
    svc = DecompressionService(cache_blocks=10 ** 9)
    svc.attach("s", store)
    svc.read("s", 17, 19)
    misses0 = svc.stats["cache_misses"]
    svc.read("s", 17, 19)  # identical request: served from cache
    assert svc.stats["cache_misses"] == misses0
    assert svc.stats["cache_hits"] >= 1

    # a tiny budget must evict instead of growing without bound
    small = DecompressionService(cache_blocks=4)
    small.attach("s", store)
    small.read("s", 0, store.total_blocks(0))
    assert small._cached_blocks <= 4


def test_decompression_service_deadline_injected_clock():
    t = [0.0]
    svc = DecompressionService(policy=FlushPolicy(max_age_s=0.5),
                               clock=lambda: t[0])
    svc.attach("s", pack(_golden_bytes("std_D1")))
    y = decode_stream(_golden_bytes("std_D1"))
    assert svc.submit("a", "s", 1, 3) is None
    assert svc.poll() is None          # young batch: no flush
    t[0] = 0.6
    out = svc.poll()                   # deadline expired: flush now
    np.testing.assert_array_equal(out["a"], y[16:3 * 16])
    assert svc.poll() is None          # deadline rearmed


def test_flush_isolates_failing_group():
    """A corrupt store must fail alone: healthy requests in the same flush
    still get their answers; the failed ids surface in last_errors."""
    blob = _session_stream("std_D32")
    y = decode_stream(blob)
    good = pack(blob)
    bad = bytearray(good)
    # corrupt the first decision byte of a mid-stream chunk body (0xFF = a
    # bogus overwrite prefix => the walk consumes a phantom 130-byte miss
    # and misses the indexed chunk length); the footer CRC covers only the
    # index, so attach-time validation passes
    store = Container(good)
    off = (int(store._cols["offset"][store.n_chunks - 2])
           + stream_mod._HDR.size)  # tail-less mid segment: body starts here
    bad[off] = 0xFF
    svc = DecompressionService(policy=FlushPolicy(max_batch_streams=2))
    svc.attach("good", good)
    svc.attach("bad", bytes(bad))
    nb = store.total_blocks(0)
    assert svc.submit("rb", "bad", 0, nb) is None
    ans = svc.submit("rg", "good", 3, 7)
    assert set(ans) == {"rg"}
    np.testing.assert_array_equal(ans["rg"], y[3 * 16:7 * 16])
    assert isinstance(svc.last_errors["rb"], StreamFormatError)
    assert svc.stats["failed_requests"] == 1


def test_flush_mixed_length_requests():
    """Short and long requests in one flush (distinct padding buckets) all
    decode exactly."""
    blob = _session_stream("std_D32")
    y = decode_stream(blob)
    svc = DecompressionService(policy=FlushPolicy(max_batch_streams=5))
    svc.attach("s", pack(blob))
    nb = Container(pack(blob)).total_blocks(0)
    reqs = [("a", 0, 1), ("b", 5, 6), ("c", 17, 18), ("d", 0, nb)]
    for rid, i, j in reqs:
        svc.submit(rid, "s", i, j)
    ans = svc.submit("e", "s", 8, 10)
    for rid, i, j in reqs + [("e", 8, 10)]:
        np.testing.assert_array_equal(ans[rid], y[i * 16:j * 16])


def test_decode_seed_minus_one_no_warning():
    """seed=-1 masks to 2**64-1; the permutation hash must wrap silently."""
    import warnings
    blob = _golden_bytes("std_D32")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        a = decode_stream(blob, seed=-1)
        store = Container(pack(blob))
        np.testing.assert_array_equal(
            decode_range(store, 0, 40, seed=-1), a[:40 * 16])


# ------------------------------------------------------- hypothesis ranges
try:
    import hypothesis  # noqa: F401

    from hypothesis import given, settings
    from hypothesis import strategies as st

    _PREPPED = {}

    def _prepped(name):
        if name not in _PREPPED:
            blob = _session_stream(name)
            _PREPPED[name] = (Container(pack(blob)), decode_stream(blob))
        return _PREPPED[name]

    @given(name=st.sampled_from(sorted(GOLDEN_CASES)),
           data=st.data())
    @settings(max_examples=60, deadline=None)
    def test_random_ranges_property(name, data):
        """Property form of the acceptance criterion: ANY range of ANY
        golden-case container equals the sequential decode's slice."""
        store, y = _prepped(name)
        nb = store.total_blocks(0)
        B = store.header_of(int(store.chunks_of(0)[0])).block_size
        i = data.draw(st.integers(min_value=0, max_value=nb - 1))
        j = data.draw(st.integers(min_value=i + 1, max_value=nb))
        np.testing.assert_array_equal(decode_range(store, i, j),
                                      y[i * B:j * B])

except ImportError:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_ranges_property():
        pass
