"""Attention paths: banded sliding-window vs reference, decode ring buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import _flash, _flash_banded


def _qkv(B=2, S=256, H=4, hd=16, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    return mk(), mk(), mk()


@pytest.mark.parametrize("window,chunk", [(32, 32), (64, 32), (96, 32), (32, 16)])
def test_banded_matches_full_window_mask(window, chunk):
    q, k, v = _qkv()
    pos = jnp.arange(q.shape[1], dtype=jnp.int32)
    ref = _flash(q, k, v, pos, pos, causal=True, window=window,
                 chunk=q.shape[1])  # single chunk => full masked path
    out = _flash_banded(q, k, v, pos, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_banded_grads_finite():
    q, k, v = _qkv(S=128)
    pos = jnp.arange(128, dtype=jnp.int32)
    g = jax.grad(lambda q: _flash_banded(q, k, v, pos, window=64,
                                         chunk=32).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_flash_matches_naive_softmax_causal():
    q, k, v = _qkv(S=64)
    pos = jnp.arange(64, dtype=jnp.int32)
    out = _flash(q, k, v, pos, pos, causal=True, window=None, chunk=16)
    # naive reference
    s = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(q.shape[-1])
    mask = pos[:, None] >= pos[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(s, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


@given(st.integers(min_value=1, max_value=4), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_flash_chunking_invariance(chunks, seed):
    """Result must not depend on the chunk size (online softmax exactness)."""
    q, k, v = _qkv(B=1, S=64, seed=seed)
    pos = jnp.arange(64, dtype=jnp.int32)
    full = _flash(q, k, v, pos, pos, causal=True, window=None, chunk=64)
    part = _flash(q, k, v, pos, pos, causal=True, window=None,
                  chunk=64 // (2 ** (chunks - 1)) or 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(part), atol=2e-3)
