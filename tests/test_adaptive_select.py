"""Adaptive per-channel mode selection (DESIGN.md Sec. 11): the selector's
predictor/hysteresis policy as a unit, and the streaming session wiring
(segment-boundary switches, per-channel codecs, heterogeneous decode)."""
import numpy as np
import pytest

from repro.core import IdealemCodec
from repro.core.select import ChannelSelector, SelectorConfig
from repro.core.stream import decode_stream, parse_stream


def _noise(n, seed=0):
    return np.random.default_rng(seed).normal(0.0, 1.0, n)


def _smooth(n, seed=0):
    # heavily autocorrelated: rho1 ~ 1
    t = np.arange(n)
    return np.sin(t * 0.01) * 5 + _noise(n, seed) * 0.01


# ----------------------------------------------------------------- selector
def test_warmup_gates_predictors():
    sel = ChannelSelector(block_size=16, config=SelectorConfig(
        warmup_blocks=4))
    sel.observe(_noise(16 * 3))
    assert sel.predictors() is None
    assert sel.decide(3) is None          # no decision while warming up
    sel.observe(_noise(16))
    assert sel.predictors() is not None
    assert sel.events == []


def test_predictors_separate_regimes():
    sel = ChannelSelector(block_size=32)
    sel.observe(_noise(32 * 8))
    rho_noise, _, _ = sel.predictors()
    sel2 = ChannelSelector(block_size=32)
    sel2.observe(_smooth(32 * 8))
    rho_smooth, _, _ = sel2.predictors()
    assert rho_noise < 0.35 < 0.7 < rho_smooth


def test_smooth_signal_switches_to_delta_and_sticks():
    cfg = SelectorConfig(warmup_blocks=4, patience=2, min_dwell_blocks=8)
    sel = ChannelSelector(block_size=32, mode="std", config=cfg)
    events = []
    for i in range(20):
        sel.observe(_smooth(32, seed=i))
        ev = sel.decide((i + 1) * 1)
        if ev is not None:
            events.append(ev)
    assert len(events) == 1               # one switch, then stable
    assert events[0].old_mode == "std" and events[0].new_mode == "delta"
    assert sel.mode == "delta"


def test_patience_requires_consecutive_targets():
    cfg = SelectorConfig(warmup_blocks=4, patience=3, min_dwell_blocks=0)
    sel = ChannelSelector(block_size=32, mode="std", config=cfg)
    sel.observe(_smooth(32 * 4))
    assert sel.decide(4) is None          # streak 1
    assert sel.decide(5) is None          # streak 2
    assert sel.decide(6) is not None      # streak 3 == patience
    assert sel.mode == "delta"


def test_min_dwell_blocks_spaces_switches():
    cfg = SelectorConfig(warmup_blocks=4, patience=1, min_dwell_blocks=100)
    sel = ChannelSelector(block_size=32, mode="std", config=cfg)
    sel.observe(_smooth(32 * 4))
    assert sel.decide(10) is not None     # first switch commits
    sel.observe(_noise(32 * 4))           # regime flips right back
    assert sel.decide(50) is None         # inside the dwell window
    assert sel.decide(109) is None
    assert sel.decide(110) is not None    # dwell elapsed


def test_mode_boundaries_are_sticky():
    """The rho1 boundary moves AWAY from the current mode by the hysteresis
    margin, so a value inside the band never flaps."""
    cfg = SelectorConfig(hysteresis=0.1, residual_rho=0.35, delta_rho=0.7)
    lo = ChannelSelector(block_size=16, mode="std", config=cfg)
    hi = ChannelSelector(block_size=16, mode="residual", config=cfg)
    for rho in (0.30, 0.36, 0.44):        # inside [0.25, 0.45): ambiguous
        assert lo._target_mode(rho) == "std"
        assert hi._target_mode(rho) == "residual"
    assert lo._target_mode(0.46) == "residual"   # cleared 0.35 + 0.1
    assert hi._target_mode(0.24) == "std"        # cleared 0.35 - 0.1


def test_scale_tightens_and_relaxes_with_hysteresis():
    cfg = SelectorConfig(drift_hi=0.5, drift_lo=0.2, d_crit_scales=(0.75, 1.0))
    sel = ChannelSelector(block_size=16, config=cfg)
    assert sel._target_scale(1.0, 0.1) == 1.0
    assert sel._target_scale(1.0, 0.6) == 0.75   # drift above drift_hi
    sel.scale = 0.75
    assert sel._target_scale(1.0, 0.3) == 0.75   # still above drift_lo
    assert sel._target_scale(1.0, 0.1) == 1.0    # settled: relax


def test_selector_validation():
    with pytest.raises(ValueError, match="warmup_blocks"):
        ChannelSelector(16, config=SelectorConfig(warmup_blocks=1))
    with pytest.raises(ValueError, match="mode"):
        ChannelSelector(16, mode="huffman")


# ----------------------------------------------------- session integration
def _regime_signal(n_half, seed=0):
    return np.concatenate([_noise(n_half, seed), _smooth(n_half, seed + 1)])


def _run_adaptive(backend, x, feed=256):
    codec = IdealemCodec(
        mode="std", block_size=16, num_dict=32, alpha=0.05, backend=backend,
        adaptive=True,
        selector=SelectorConfig(warmup_blocks=4, patience=2,
                                min_dwell_blocks=16))
    s = codec.session()
    segs = [s.feed(x[lo:lo + feed]) for lo in range(0, len(x), feed)]
    segs.append(s.finish())
    return b"".join(segs), s.stats


def test_adaptive_session_switches_and_decodes():
    x = _regime_signal(16 * 200)
    blob, stats = _run_adaptive("numpy", x)
    assert stats.mode_switches >= 1
    assert stats.events and stats.events[0]["old_mode"] == "std"
    y = decode_stream(blob)
    assert len(y) == len(x)
    # the stream really is heterogeneous: the single-section parser must
    # refuse it (decode_stream is the documented entry point)
    from repro.core.stream import StreamFormatError
    with pytest.raises(StreamFormatError, match="decode_stream"):
        parse_stream(blob)


def test_adaptive_numpy_jax_agree():
    x = _regime_signal(16 * 120, seed=3)
    blob_np, st_np = _run_adaptive("numpy", x)
    blob_j, st_j = _run_adaptive("jax", x)
    assert blob_np == blob_j
    assert st_np.mode_switches == st_j.mode_switches


def test_adaptive_requires_streaming():
    codec = IdealemCodec(mode="std", block_size=16, adaptive=True)
    with pytest.raises(ValueError, match="streaming-only"):
        codec.encode(_noise(256))
    with pytest.raises(ValueError, match="emit_segments"):
        codec.session(emit_segments=False)


def test_stationary_channel_never_switches():
    x = _noise(16 * 300, seed=9)
    _, stats = _run_adaptive("numpy", x)
    assert stats.mode_switches == 0
