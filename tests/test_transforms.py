"""Bounded-range transform paths (paper Sec. IV-A): the 359->1 degree wrap
through residual/delta modes with value_range=(0, 360), including wraps at
block boundaries -- previously untested (ISSUE 2).
"""
import numpy as np
import pytest

from repro.core import IdealemCodec
from repro.core.transforms import (delta_forward, delta_inverse,
                                   np_wrap_centered, np_wrap_range,
                                   residual_forward, residual_inverse)


def test_paper_wrap_example_359_to_1():
    """The paper's motivating case: a 359deg -> 1deg phase move is a +2deg
    delta once wrapped into the centered interval, not -358."""
    assert np_wrap_centered(np.array([1.0 - 359.0]), 0.0, 360.0)[0] == 2.0
    # and the reconstruction wraps 359 + 2 = 361 back into [0, 360)
    assert np_wrap_range(np.array([361.0]), 0.0, 360.0)[0] == 1.0


@pytest.mark.parametrize("fwd,inv", [(residual_forward, residual_inverse),
                                     (delta_forward, delta_inverse)])
def test_wrap_roundtrip_within_and_across_blocks(fwd, inv):
    """Forward+inverse with a bounded range is exact across the 360 wrap,
    wherever the wrap lands -- mid-block or right at a block boundary."""
    blocks = np.array([
        [357.0, 358.5, 359.5, 1.25],   # wrap mid-block
        [359.0, 0.5, 2.0, 3.5],        # base just before the seam
        [0.25, 359.75, 1.0, 358.0],    # oscillating around the seam
        [10.0, 20.0, 30.0, 40.0],      # no wrap at all
    ])
    base, t = fwd(blocks, value_range=(0.0, 360.0))
    # every transformed magnitude must be the short way around (< 180)
    assert float(np.max(np.abs(np.asarray(t)))) < 180.0
    y = inv(base, t, value_range=(0.0, 360.0))
    np.testing.assert_allclose(np.asarray(y), blocks, atol=1e-9)


@pytest.mark.parametrize("mode", ["residual", "delta"])
def test_codec_roundtrip_wrap_at_block_boundaries(mode):
    """End-to-end: blocks deliberately cut so bases land at 359.x and the
    first in-block step crosses the seam; an all-miss encode must decode
    the original angles exactly (misses are stored verbatim)."""
    B = 8
    # distinct per-block slopes => distinct transformed extremes => with
    # rel_tol=0 every block misses, so decode is the verbatim path
    blocks = np.stack([
        np.mod(359.0 + np.arange(B) * (0.7 + 0.31 * k), 360.0)
        for k in range(6)
    ])
    x = blocks.ravel()
    codec = IdealemCodec(mode=mode, block_size=B, num_dict=4, alpha=0.05,
                         rel_tol=0.0, value_range=(0.0, 360.0),
                         backend="numpy")
    y = codec.decode(codec.encode(x))
    from repro.core.stream import parse_stream
    _, events = parse_stream(codec.encode(x))
    assert all(e["kind"] == "miss" for e in events)
    np.testing.assert_allclose(y, x, atol=1e-9)


@pytest.mark.parametrize("mode", ["residual", "delta"])
def test_codec_wrap_rescues_hit_rate_on_angle_ramp(mode):
    """A steady phase ramp (the paper's uPMU ANG channels) is one repeating
    source distribution once wrapped: with value_range set, every block
    after the first hits; without it, each 360 crossing forces misses."""
    B = 16
    # slope 21/8: binary-exact (deltas reproduce bitwise) with a 137.14-
    # sample period, so the 360-crossing drifts across block positions and
    # unwrapped blocks cannot accidentally match each other
    x = np.mod(0.5 + 2.625 * np.arange(B * 64), 360.0)  # ~7 wraps
    kw = dict(mode=mode, block_size=B, num_dict=32, alpha=0.05, rel_tol=0.5,
              backend="numpy")
    wrapped = IdealemCodec(value_range=(0.0, 360.0), **kw)
    st = wrapped.encode_stats(x)
    assert st["hits"] == st["blocks"] - 1  # everything hits the first entry
    np.testing.assert_allclose(wrapped.decode(wrapped.encode(x)), x,
                               atol=1e-9)
    naive = IdealemCodec(value_range=None, **kw)
    assert naive.encode_stats(x)["hits"] < st["hits"]
