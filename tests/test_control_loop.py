"""Control loop unit tests: synthetic stage histograms on a scratch
registry drive every decision branch -- batch sizing against the latency
target, pipeline-depth switching on stage balance, drift-triggered
autotune re-probes, and the min-observation gate (ISSUE 10)."""
import pytest

from repro import obs
from repro.serve import FlushPolicy
from repro.serve.control import STAGES, ControlConfig, ControlLoop


def make_loop(policy=None, reg=None, fired=None, **cfg):
    reg = reg if reg is not None else obs.MetricsRegistry()
    hists = {s: reg.histogram("repro_serve_stage_seconds", "stage wall",
                              labels={"stage": s}) for s in STAGES}
    loop = ControlLoop(
        policy=policy or FlushPolicy(max_batch_blocks=4096, max_age_s=0.1),
        config=ControlConfig(min_observations=4, **cfg), registry=reg,
        on_reprobe=(lambda: fired.append(1)) if fired is not None
        else (lambda: None))
    return loop, hists


def observe(hists, n, host_s, reconstruct_s):
    for _ in range(n):
        for s, h in hists.items():
            h.observe(reconstruct_s if s == "reconstruct" else host_s)


def test_no_histograms_is_a_clean_noop():
    loop = ControlLoop(policy=FlushPolicy(),
                       registry=obs.MetricsRegistry(),
                       on_reprobe=lambda: None)
    d = loop.tick()
    assert not d.changed and not d.reprobed and d.p99_s is None


def test_below_min_observations_holds_policy():
    loop, hists = make_loop()
    observe(hists, 2, host_s=1.0, reconstruct_s=1.0)  # loud but sparse
    d = loop.tick()
    assert not d.changed and d.p99_s is None


def test_over_target_halves_batch_and_deadline():
    loop, hists = make_loop()
    observe(hists, 16, host_s=0.002, reconstruct_s=0.08)
    d = loop.tick()
    assert d.changed
    assert d.policy.max_batch_blocks == 2048
    assert d.policy.max_age_s == pytest.approx(0.05)
    assert any("max_batch_blocks" in r for r in d.reasons)


def test_under_watermark_doubles_back_up():
    loop, hists = make_loop()
    observe(hists, 16, host_s=0.0005, reconstruct_s=0.0005)
    d = loop.tick()
    assert d.changed and d.policy.max_batch_blocks == 8192
    assert d.policy.max_age_s == pytest.approx(0.2)


def test_batch_clamps_at_bounds():
    lo, hists = make_loop(policy=FlushPolicy(max_batch_blocks=256,
                                             max_age_s=0.002))
    observe(hists, 16, host_s=0.002, reconstruct_s=0.08)
    d = lo.tick()  # already at min_batch_blocks/min_age_s: nothing to halve
    assert d.policy.max_batch_blocks == 256
    assert d.policy.max_age_s == pytest.approx(0.002)

    hi, hists = make_loop(policy=FlushPolicy(max_batch_blocks=1 << 16,
                                             max_age_s=0.5))
    observe(hists, 16, host_s=0.0001, reconstruct_s=0.0001)
    d = hi.tick()
    assert d.policy.max_batch_blocks == 1 << 16
    assert d.policy.max_age_s == pytest.approx(0.5)


def test_pipeline_depth_follows_stage_balance():
    loop, hists = make_loop()
    # device stage dominates -> overlap pays -> depth 2
    observe(hists, 16, host_s=0.001, reconstruct_s=0.02)
    assert loop.tick().policy.pipeline_depth == 2
    # host dominates -> overlap is overhead -> back to 1
    observe(hists, 16, host_s=0.01, reconstruct_s=0.001)
    assert loop.tick().policy.pipeline_depth == 1


def test_drift_triggers_reprobe_against_best_baseline():
    fired = []
    loop, hists = make_loop(fired=fired)
    observe(hists, 16, host_s=0.0005, reconstruct_s=0.001)  # pins baseline
    assert not loop.tick().reprobed
    observe(hists, 16, host_s=0.0005, reconstruct_s=0.0005)  # improves it
    assert not loop.tick().reprobed
    observe(hists, 16, host_s=0.0005, reconstruct_s=0.005)   # 10x the best
    d = loop.tick()
    assert d.reprobed and fired == [1]
    assert any("re-probe" in r for r in d.reasons)


def test_reprobe_repins_baseline_no_thrash():
    fired = []
    loop, hists = make_loop(fired=fired)
    observe(hists, 16, host_s=0.0005, reconstruct_s=0.001)
    loop.tick()
    observe(hists, 16, host_s=0.0005, reconstruct_s=0.01)
    assert loop.tick().reprobed
    # the same (drifted) latency again is now the baseline: no second probe
    observe(hists, 16, host_s=0.0005, reconstruct_s=0.01)
    assert not loop.tick().reprobed
    assert fired == [1]


def test_interval_deltas_forget_history():
    loop, hists = make_loop()
    observe(hists, 64, host_s=0.002, reconstruct_s=0.08)  # slow era
    loop.tick()
    observe(hists, 16, host_s=0.0001, reconstruct_s=0.0001)  # fast era
    d = loop.tick()
    # a cumulative-quantile controller would still think we are slow
    assert d.p99_s < 0.01


def test_status_shape():
    loop, hists = make_loop()
    observe(hists, 16, host_s=0.002, reconstruct_s=0.08)
    loop.tick()
    st = loop.status()
    assert st["ticks"] == 1
    assert set(st["policy"]) == {"max_batch_blocks", "max_batch_streams",
                                 "max_age_s", "pipeline_depth"}
    assert st["last_p99_s"] > 0
    assert st["last_reasons"]


def test_decision_ring_is_bounded():
    loop, hists = make_loop()
    for _ in range(80):
        loop.tick()
    assert len(loop.decisions) == 64


def test_flush_policy_with_updates_and_as_dict():
    p = FlushPolicy(max_batch_blocks=100, max_age_s=0.5)
    q = p.with_updates(max_batch_blocks=50)
    assert (q.max_batch_blocks, q.max_age_s) == (50, 0.5)
    assert p.max_batch_blocks == 100  # frozen original untouched
    assert q.as_dict()["max_batch_blocks"] == 50
