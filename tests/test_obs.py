"""Unified telemetry layer (ISSUE 8): registry, tracer, exporters, wiring.

Four property groups: (1) the instruments themselves -- counter/gauge/
histogram semantics, Prometheus ``le`` bucket boundaries, label children,
type-conflict rejection, and exact totals under concurrent writers; (2)
the span tracer -- parent/child nesting per thread, error status, bounded
ring eviction, exporter isolation; (3) the exporters -- a golden-format
Prometheus text pin, the parse round trip, and the JSON snapshot shape;
(4) the wiring -- the acceptance shape of ISSUE 8: ONE pipelined
``DecompressionService`` flush with ``backend="auto"`` must land stage
latency histograms for all four stages, autotune probe/hit counters,
cache hit counters and valid round-trippable exposition in a single
process-default registry snapshot.

Wiring tests assert *deltas* against the process-default registry (other
tests in the same pytest process write into it too; absolute values are
not meaningful there).
"""
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import MetricsRegistry, SpanTracer
from repro.serve import (DecompressionService, FlushPolicy, StagePipeline,
                         StreamCoalescer, SyncExecutor, ThreadStageExecutor)
from repro.store import Container, pack


# --------------------------------------------------------------- registry

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("t_ops_total", "ops")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("t_depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0
    assert reg.get_value("t_ops_total") == 3.5
    assert reg.get_value("t_never_written_total") == 0.0


def test_label_children_are_distinct_and_cached():
    reg = MetricsRegistry()
    a = reg.counter("t_total", labels={"k": "a"})
    b = reg.counter("t_total", labels={"k": "b"})
    a.inc(1)
    b.inc(2)
    assert (a.value, b.value) == (1.0, 2.0)
    # same (name, labels) returns the same child, label order irrelevant
    c = reg.counter("t2_total", labels={"x": "1", "y": "2"})
    assert reg.counter("t2_total", labels={"y": "2", "x": "1"}) is c


def test_type_and_bucket_conflicts_raise():
    reg = MetricsRegistry()
    reg.counter("t_total")
    with pytest.raises(ValueError):
        reg.gauge("t_total")
    reg.histogram("t_seconds", buckets=(0.1, 1.0))
    with pytest.raises(ValueError):
        reg.histogram("t_seconds", buckets=(0.5, 1.0))
    with pytest.raises(ValueError):
        reg.counter("bad name!")
    with pytest.raises(ValueError):
        reg.counter("t3_total", labels={"bad-label": "v"})


def test_histogram_bucket_boundaries():
    """Prometheus ``le`` semantics: a value exactly on a bound lands in
    that bucket; above the last bound lands in +Inf."""
    reg = MetricsRegistry()
    h = reg.histogram("t_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.1, 1.0, 10.0):   # exactly on each bound
        h.observe(v)
    h.observe(0.05)              # below the first
    h.observe(10.0001)           # above the last -> +Inf
    assert h.bucket_counts() == (2, 1, 1, 1)  # per-bucket, +Inf last
    assert h.count == 5
    assert h.sum == pytest.approx(0.1 + 1.0 + 10.0 + 0.05 + 10.0001)


def test_default_latency_ladder():
    b = obs.DEFAULT_LATENCY_BUCKETS
    assert len(b) == 15 and b[0] == pytest.approx(1e-6) and b[-1] == 10.0
    assert list(b) == sorted(b)


def test_reset_keeps_handles_valid():
    reg = MetricsRegistry()
    c = reg.counter("t_total")
    h = reg.histogram("t_seconds")
    c.inc(7)
    h.observe(0.5)
    reg.reset()
    assert c.value == 0.0 and h.count == 0
    c.inc()  # the cached handle still writes into the registry
    assert reg.get_value("t_total") == 1.0


def test_disabled_registry_drops_writes():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("t_total")
    h = reg.histogram("t_seconds")
    c.inc(5)
    h.observe(1.0)
    assert c.value == 0.0 and h.count == 0
    reg.enabled = True
    c.inc()
    assert c.value == 1.0


def test_registry_thread_safety_exact_totals():
    """Concurrent flush simulation: many writers on shared and per-thread
    instruments, exact totals at the end (no lost updates)."""
    reg = MetricsRegistry()
    shared = reg.counter("t_shared_total")
    hist = reg.histogram("t_lat_seconds")
    n_threads, n_iter = 8, 2000

    def worker(i):
        own = reg.counter("t_labeled_total", labels={"w": str(i)})
        for _ in range(n_iter):
            shared.inc()
            own.inc()
            hist.observe(1e-4)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert shared.value == n_threads * n_iter
    assert hist.count == n_threads * n_iter
    for i in range(n_threads):
        assert reg.get_value("t_labeled_total", {"w": str(i)}) == n_iter


# ----------------------------------------------------------------- tracer

def test_span_nesting_parent_ids():
    trc = SpanTracer()
    with trc.span("outer") as outer_id:
        with trc.span("inner") as inner_id:
            trc.event("tick")
    recs = {r.name: r for r in trc.records()}
    assert recs["inner"].parent_id == outer_id
    assert recs["outer"].parent_id is None
    assert recs["tick"].parent_id == inner_id  # events nest under spans
    assert recs["tick"].kind == "event" and recs["tick"].duration_s == 0.0
    # inner finished first (ring is completion-ordered)
    names = [r.name for r in trc.records()]
    assert names == ["tick", "inner", "outer"]


def test_span_error_status_and_reraise():
    trc = SpanTracer()
    with pytest.raises(RuntimeError):
        with trc.span("boom"):
            raise RuntimeError("x")
    (rec,) = trc.records(name="boom")
    assert rec.status == "error" and rec.duration_s >= 0.0


def test_span_ring_eviction():
    trc = SpanTracer(capacity=3)
    for i in range(7):
        trc.event(f"e{i}")
    assert [r.name for r in trc.records()] == ["e4", "e5", "e6"]


def test_span_threads_nest_independently():
    trc = SpanTracer()
    err = []

    def worker(tag):
        try:
            with trc.span(f"{tag}.outer") as oid:
                with trc.span(f"{tag}.inner"):
                    pass
                assert trc._stack()[-1] == oid
        except BaseException as e:  # pragma: no cover - diagnostic
            err.append(e)

    threads = [threading.Thread(target=worker, args=(f"t{i}",))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not err
    for i in range(4):
        (inner,) = trc.records(name=f"t{i}.inner")
        (outer,) = trc.records(name=f"t{i}.outer")
        assert inner.parent_id == outer.span_id


def test_exporters_receive_records_and_bad_ones_are_dropped():
    trc = SpanTracer()
    seen = []
    calls = []

    def good(rec):
        seen.append(rec.name)

    def bad(rec):
        calls.append(rec.name)
        raise ValueError("poison")

    trc.add_exporter(good)
    trc.add_exporter(bad)
    trc.event("a")
    trc.event("b")
    assert seen == ["a", "b"]
    assert calls == ["a"]  # dropped after the first raise


def test_disabled_tracer_records_nothing():
    trc = SpanTracer(enabled=False)
    with trc.span("s") as sid:
        assert sid is None
    trc.event("e")
    assert trc.records() == []


# -------------------------------------------------------------- exporters

def _golden_registry():
    reg = MetricsRegistry()
    reg.counter("t_ops_total", "ops", labels={"op": "read"}).inc(2)
    reg.gauge("t_depth").set(1.5)
    h = reg.histogram("t_lat_seconds", "lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    return reg


def test_prometheus_golden_text():
    text = obs.to_prometheus(_golden_registry())
    assert text == (
        "# TYPE t_depth gauge\n"
        "t_depth 1.5\n"
        "# HELP t_lat_seconds lat\n"
        "# TYPE t_lat_seconds histogram\n"
        't_lat_seconds_bucket{le="0.1"} 1\n'
        't_lat_seconds_bucket{le="1"} 2\n'
        't_lat_seconds_bucket{le="+Inf"} 3\n'
        "t_lat_seconds_sum 5.55\n"
        "t_lat_seconds_count 3\n"
        "# HELP t_ops_total ops\n"
        "# TYPE t_ops_total counter\n"
        't_ops_total{op="read"} 2\n'
    )


def test_prometheus_parse_round_trip():
    reg = _golden_registry()
    parsed = obs.parse_prometheus(obs.to_prometheus(reg))
    assert parsed[("t_ops_total", (("op", "read"),))] == 2.0
    assert parsed[("t_depth", ())] == 1.5
    assert parsed[("t_lat_seconds_bucket", (("le", "+Inf"),))] == 3.0
    assert parsed[("t_lat_seconds_count", ())] == 3.0


def test_prometheus_label_escapes_round_trip():
    reg = MetricsRegistry()
    awkward = 'weird"\\label\nwith newline'
    reg.counter("t_total", labels={"op": awkward}).inc()
    parsed = obs.parse_prometheus(obs.to_prometheus(reg))
    assert parsed[("t_total", (("op", awkward),))] == 1.0


def test_json_snapshot_shape():
    reg = _golden_registry()
    trc = SpanTracer()
    with trc.span("s"):
        pass
    doc = obs.to_json(reg, trc)
    assert doc["version"] == 1
    hist = doc["metrics"]["t_lat_seconds"]
    assert hist["kind"] == "histogram"
    (entry,) = hist["values"]
    assert entry["count"] == 3 and entry["buckets"]["+Inf"] == 1
    assert doc["spans"][0]["name"] == "s"
    import json
    json.loads(json.dumps(doc))  # JSON-ready, no numpy scalars etc.


def test_selfcheck_clean():
    assert obs.selfcheck() == []


# ----------------------------------------------------------------- wiring

def _get(name, labels=None):
    return obs.registry().get_value(name, labels)


def _stage_counts():
    snap = obs.registry().snapshot()
    fam = snap.get("repro_serve_stage_seconds", {"values": []})
    return {v["labels"].get("stage"): v.get("count", 0)
            for v in fam["values"]}


def test_coalescer_flush_metrics_and_span():
    """A coalesced encode flush moves the pinned encode metric names and
    records an ``encode.flush`` span."""
    before = {k: _get(k) for k in (
        "repro_encode_flushes_total", "repro_encode_bytes_in_total",
        "repro_encode_bytes_out_total", "repro_encode_blocks_total")}
    spans_before = len(obs.tracer().records(name="encode.flush"))
    rng = np.random.default_rng(0)
    coal = StreamCoalescer(
        policy=FlushPolicy(max_batch_blocks=64, max_batch_streams=4),
        mode="std", block_size=16, num_dict=8)
    blobs = {}
    for sid in ("a", "b"):
        coal.open_stream(sid)
        blobs[sid] = b""
    for _ in range(3):
        for sid in blobs:
            out = coal.submit(sid, rng.normal(0, 1, size=64)) or {}
            for k, seg in out.items():
                blobs[k] += seg
    for sid in list(blobs):
        blobs[sid] += coal.close_stream(sid)
    for key, prev in before.items():
        assert _get(key) > prev, key
    assert len(obs.tracer().records(name="encode.flush")) > spans_before
    assert all(blobs.values())


def test_pipelined_auto_flush_single_snapshot_acceptance():
    """ISSUE 8 acceptance: one pipelined ``DecompressionService`` flush
    with ``backend="auto"`` yields, from a single registry snapshot:
    per-stage latency histograms for all four stages, autotune probe/hit
    counters, cache hit counters, and exposition text that parses back."""
    rng = np.random.default_rng(1)
    coal = StreamCoalescer(
        policy=FlushPolicy(max_batch_blocks=256, max_batch_streams=2),
        mode="std", block_size=16, num_dict=8)
    coal.open_stream("s")
    blob = b""
    for _ in range(4):
        out = coal.submit("s", rng.normal(0, 1, size=256)) or {}
        blob += out.get("s", b"")
    blob += coal.close_stream("s")

    stages_before = _stage_counts()
    tuning_before = sum(
        v["value"] for fam in ("repro_tuning_probes_total",
                               "repro_tuning_hits_total")
        for v in obs.registry().snapshot().get(
            fam, {"values": []})["values"])
    cache_before = (_get("repro_serve_cache_hits_total"),
                    _get("repro_serve_cache_misses_total"))

    svc = DecompressionService(
        policy=FlushPolicy(max_batch_streams=8, pipeline_depth=2),
        backend="auto")
    svc.attach("s", Container(pack(blob)))
    # two flush cycles over the same chunk: the first parse is the miss,
    # the second flush's parse must hit the segment LRU
    answers = {}
    for i, (lo, hi) in enumerate([(0, 8), (4, 12)]):
        svc.submit(f"r{i}", "s", lo, hi)
    answers.update(svc.flush())
    for i, (lo, hi) in enumerate([(2, 10), (0, 16)], start=2):
        svc.submit(f"r{i}", "s", lo, hi)
    answers.update(svc.flush())
    answers.update(svc.close())
    assert set(answers) == {"r0", "r1", "r2", "r3"}

    snap = obs.registry().snapshot()  # ONE snapshot, all of it below
    stages = {v["labels"].get("stage"): v.get("count", 0)
              for v in snap["repro_serve_stage_seconds"]["values"]}
    for stage in ("plan", "gather", "reconstruct", "emit"):
        assert stages.get(stage, 0) > stages_before.get(stage, 0), stage
    tuning_after = sum(
        v["value"] for fam in ("repro_tuning_probes_total",
                               "repro_tuning_hits_total")
        for v in snap.get(fam, {"values": []})["values"])
    assert tuning_after > tuning_before  # auto routed through the tuner
    hits_after = (_get("repro_serve_cache_hits_total"),
                  _get("repro_serve_cache_misses_total"))
    assert hits_after[0] > cache_before[0]
    assert hits_after[1] > cache_before[1]
    # the whole registry must export as valid, parseable exposition text
    parsed = obs.parse_prometheus(obs.to_prometheus())
    assert parsed[("repro_serve_cache_hits_total", ())] == hits_after[0]
    count_key = ("repro_serve_stage_seconds_count", (("stage", "plan"),))
    assert parsed[count_key] == float(stages["plan"])


def test_decode_stats_compat_view():
    """``decode_stats()`` stays a plain int dict (the pinned pre-obs
    API) while its storage lives on the registry."""
    from repro.core.decode import decode_stats
    stats = decode_stats()
    for key in ("host_calls", "device_calls", "fallbacks",
                "autotune_probes", "autotune_hits"):
        assert isinstance(stats[key], int)
    assert _get("repro_decode_host_calls_total") == stats["host_calls"]


# -------------------------------------------------------------- executors

def test_thread_executor_shutdown_idempotent_and_submit_after():
    ex = ThreadStageExecutor()
    assert ex.submit(lambda: 42).result() == 42
    ex.shutdown()
    ex.shutdown()  # second call must be a no-op, not a hang or raise
    fut = ex.submit(lambda: 1)
    with pytest.raises(RuntimeError, match="shut down"):
        fut.result()
    ex._thread.join(timeout=5)
    assert not ex.alive


def test_stage_pipeline_counts_stage_errors():
    before = _get("repro_serve_stage_errors_total")
    pipe = StagePipeline(SyncExecutor(), depth=1)
    ((meta, value, exc),) = pipe.push("m", lambda: 1 // 0)
    assert meta == "m" and value is None
    assert isinstance(exc, ZeroDivisionError)
    assert _get("repro_serve_stage_errors_total") == before + 1
