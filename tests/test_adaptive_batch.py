"""Batched mixed-mode device encode (ISSUE 9, DESIGN.md Sec. 13).

The adaptive session's per-channel codec variants (mode, payload width,
quantized d_crit, error-bound arm) become masked lanes of ONE padded
device scan.  These tests pin:

  * byte identity of the batched scan vs the per-channel loop (the PR 7
    path, forced via ``REPRO_ADAPTIVE_LOOP``) across backends, error
    bounds, f16 channels, feed schedules and mid-stream switches;
  * the numpy oracle (``encode_decisions_mixed_np``) against the device
    mixed scan on padded heterogeneous cohorts, chunked and one-shot;
  * the dispatch contract: ONE encode dispatch per feed regardless of
    channel count (``repro_encode_dispatches_total{path=...}``);
  * adaptive sessions through a channel-sharded encode plan, and the
    adaptive ``StreamCoalescer`` cohort flush vs per-stream sessions;
  * a hypothesis fuzz over drawn per-channel switch schedules (scaled up
    by the nightly ``HYPOTHESIS_PROFILE=ci`` run).
"""
import os

import numpy as np
import pytest

from repro import obs
from repro.core import IdealemCodec
from repro.core.encoder import (encode_decisions, encode_decisions_mixed,
                                init_state, repad_state_n)
from repro.core.npref import encode_decisions_mixed_np
from repro.core.select import SelectorConfig
from repro.core.session import _ADAPTIVE_LOOP_ENV, MixedCohort
from repro.core.stream import decode_stream

SEL = SelectorConfig(warmup_blocks=4, patience=2, min_dwell_blocks=16)
B = 16


def _signals(C, n, seed=0):
    """Heterogeneous channels: noise (stays std), trend (switches to
    delta), smooth (switches) -- rotated over C channels."""
    rng = np.random.default_rng(seed)
    t = np.arange(n, dtype=np.float64)
    base = [rng.normal(0.0, 1.0, n),
            0.03 * t + rng.normal(0, 0.02, n),
            np.sin(t * 0.02) * 4 + rng.normal(0, 0.01, n)]
    return np.stack([base[ci % 3] for ci in range(C)])


def _run(backend, data, *, feed, eb=None, dtype=np.float64, plan=None):
    kw = dict(mode="std", block_size=B, num_dict=8, backend=backend,
              adaptive=True, selector=SEL)
    if eb is not None:
        kw["error_bound"] = eb
    codec = IdealemCodec(**kw)
    s = codec.session(channels=data.shape[0], dtype=dtype, plan=plan)
    segs = [s.feed(data[:, lo:lo + feed])
            for lo in range(0, data.shape[1], feed)]
    segs.append(s.finish())
    return segs, s


# ------------------------------------------------- batched vs loop identity
@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("eb", [None, 0.6])
@pytest.mark.parametrize("feed", [96, B * 40])  # chunked vs one-shot
def test_batched_matches_loop(monkeypatch, backend, eb, feed):
    data = _signals(3, B * 40, seed=1)
    a, sa = _run(backend, data, feed=feed, eb=eb)
    monkeypatch.setenv(_ADAPTIVE_LOOP_ENV, "1")
    b, sb = _run(backend, data, feed=feed, eb=eb)
    assert a == b
    assert sa._mixed is not None          # batched path really ran
    assert sb._mixed is None              # env forced the loop
    assert ([st.mode_switches for st in sa.stats]
            == [st.mode_switches for st in sb.stats])
    if feed < data.shape[1]:  # one-shot has no feed boundary to switch at
        assert any(st.mode_switches for st in sa.stats)


def test_batched_matches_numpy_oracle():
    data = _signals(4, B * 50, seed=2)
    a, _ = _run("numpy", data, feed=128)
    b, sb = _run("jax", data, feed=128)
    assert a == b
    assert sb._mixed is not None
    # heterogeneous streams decode channel-by-channel
    for ci in range(4):
        y = decode_stream(b"".join(seg[ci] for seg in b))
        assert len(y) == data.shape[1]


def test_f16_channels_batched(monkeypatch):
    data = _signals(2, B * 30, seed=3).astype(np.float16)
    a, sa = _run("jax", data, feed=96, dtype=np.float16)
    monkeypatch.setenv(_ADAPTIVE_LOOP_ENV, "1")
    b, _ = _run("jax", data, feed=96, dtype=np.float16)
    assert a == b
    assert sa._mixed is not None


def test_ops_matcher_falls_back_to_loop():
    data = _signals(2, B * 20, seed=4)
    kw = dict(mode="std", block_size=B, num_dict=8, backend="jax",
              adaptive=True, selector=SEL)
    ops = IdealemCodec(matcher="ops", **kw).session(channels=2)
    ref = IdealemCodec(**kw).session(channels=2)
    ops_segs = [ops.feed(data), ops.finish()]
    ref_segs = [ref.feed(data), ref.finish()]
    assert ops._mixed is None and ops._mixed_disabled  # loop fallback ran
    assert ref._mixed is not None                      # batched path ran
    for a, b in zip(ops_segs, ref_segs):
        assert a == b  # ops matcher is decision-identical to reference


# ------------------------------------------------------- dispatch contract
def test_one_dispatch_per_feed():
    def batched():
        return obs.registry().get_value("repro_encode_dispatches_total",
                                        {"path": "adaptive_batched"})

    def cohort_count():
        snap = obs.registry().snapshot().get("repro_encode_adaptive_cohort")
        return snap["values"][0]["count"] if snap and snap["values"] else 0

    before, hist_before = batched(), cohort_count()
    data = _signals(3, B * 30, seed=4)
    _, s = _run("jax", data, feed=B * 10)   # 3 feeds x 10 full blocks
    assert batched() - before == 3          # one dispatch per feed, C=3
    assert s._mixed.dispatches == 3
    assert cohort_count() - hist_before == 3


def test_loop_defers_sync_and_counts(monkeypatch):
    monkeypatch.setenv(_ADAPTIVE_LOOP_ENV, "1")

    def loop():
        return obs.registry().get_value("repro_encode_dispatches_total",
                                        {"path": "adaptive_loop"})

    before = loop()
    data = _signals(3, B * 20, seed=5)
    _, s = _run("jax", data, feed=B * 10)   # 2 feeds x 3 channels
    assert loop() - before == 6
    assert s._mixed is None


# --------------------------------------------------- direct API differential
def _cohort_case(seed=6):
    rng = np.random.default_rng(seed)
    C, D, nb, n_max = 3, 4, 20, B
    n_valid = np.array([16, 15, 12])
    blocks = np.full((C, nb, n_max), np.inf, dtype=np.float32)
    valid = np.zeros((C, nb), dtype=bool)
    for ci in range(C):
        nbi = nb - 2 * ci  # ragged block counts
        base = rng.normal(0, 1, (nbi // 2 + 1, n_valid[ci]))
        rows = np.repeat(base, 2, axis=0)[:nbi]  # near-duplicates -> hits
        blocks[ci, :nbi, :n_valid[ci]] = rows + rng.normal(
            0, 0.03, rows.shape)
        valid[ci, :nbi] = True
    kw = dict(num_dict=D, n_valid=n_valid,
              d_crit=np.array([0.5, 0.4, 0.6], np.float32),
              error_bound=0.5,
              error_cumulative=np.array([False, True, False]),
              eb_on=np.array([True, False, True]))
    return blocks, valid, kw


@pytest.mark.parametrize("matcher", [None, "fused"])
def test_mixed_matches_numpy_oracle_one_shot(matcher):
    blocks, valid, kw = _cohort_case()
    dev = encode_decisions_mixed(blocks, valid=valid, matcher=matcher, **kw)
    ref = encode_decisions_mixed_np(blocks, valid=valid, **kw)
    for d, r in zip(dev, ref):
        np.testing.assert_array_equal(np.where(valid, np.asarray(d), 0),
                                      np.where(valid, r, 0))


def test_mixed_chunked_carry_matches_one_shot():
    blocks, valid, kw = _cohort_case(seed=7)
    one = encode_decisions_mixed(blocks, valid=valid, **kw)
    st = init_state(kw["num_dict"], blocks.shape[-1], channels=3, raw=True)
    parts = []
    for lo, hi in ((0, 8), (8, 20)):
        out, st = encode_decisions_mixed(blocks[:, lo:hi],
                                         valid=valid[:, lo:hi],
                                         state=st, **kw)
        parts.append(out)
    for k in range(3):
        got = np.concatenate([np.asarray(p[k]) for p in parts], axis=1)
        np.testing.assert_array_equal(np.where(valid, got, 0),
                                      np.where(valid, np.asarray(one[k]), 0))


def test_repad_state_grow_shrink_is_safe():
    st = init_state(4, 12, channels=2, raw=True)
    wide = repad_state_n(st, 16)
    assert wide.sorted_blocks.shape[-1] == 16
    assert np.all(np.asarray(wide.sorted_blocks[..., 12:]) == np.inf)
    back = repad_state_n(wide, 12)
    np.testing.assert_array_equal(np.asarray(back.sorted_blocks),
                                  np.asarray(st.sorted_blocks))


def test_mixed_rejects_ops_matcher():
    blocks, valid, kw = _cohort_case()
    with pytest.raises(ValueError, match="mixed-mode scan"):
        encode_decisions_mixed(blocks, valid=valid, matcher="ops", **kw)


# ------------------------------------------------------------- encode plans
def test_planned_adaptive_matches_unplanned():
    from repro.launch.encode_plan import make_encode_plan
    data = _signals(3, B * 30, seed=8)
    plan = make_encode_plan(3, block_size=B).validate_adaptive()
    a, _ = _run("jax", data, feed=120)
    b, sb = _run("jax", data, feed=120, plan=plan)
    assert a == b
    assert sb._mixed is not None and sb._mixed.plan is plan


def test_dict_sharded_plan_rejected_for_adaptive():
    from repro.launch.encode_plan import make_encode_plan
    plan = make_encode_plan(2, block_size=B)._replace(dict_shards=2)
    with pytest.raises(ValueError, match="dict_shards=1"):
        plan.validate_adaptive()
    codec = IdealemCodec(mode="std", block_size=B, num_dict=8,
                         backend="jax", adaptive=True)
    with pytest.raises(ValueError, match="dict_shards=1"):
        codec.session(channels=2, plan=plan)


# --------------------------------------------------------- cohort internals
def test_cohort_lane_reset_and_grow():
    co = MixedCohort(4, 2, rel_tol=0.1)
    rng = np.random.default_rng(9)
    p = rng.normal(0, 1, (4, B)).astype(np.float32)
    co.decide([(0, p, 0.5, False, False), (1, p[:, :B - 1], 0.5, True,
               False)])
    assert co.lane_n.tolist() == [B, B - 1]
    co.reset_lane(1)
    assert co.lane_n[1] == 0
    assert not np.any(np.asarray(co.state.valid[1]))
    co.grow(4)
    assert co.capacity == 4 and co.state.valid.shape[0] == 4
    dec = co.decide([(3, p, 0.5, False, False)])
    assert dec[3][0].shape == (4,)


# ------------------------------------------------------- adaptive coalescer
def test_adaptive_coalescer_matches_sessions():
    from repro.serve.compress import StreamCoalescer
    from repro.serve.engine import FlushPolicy
    kw = dict(mode="std", block_size=B, num_dict=8, backend="jax",
              adaptive=True, selector=SEL, error_bound=0.6)
    data = _signals(3, B * 40, seed=10)
    sids = [f"s{ci}" for ci in range(3)]
    co = StreamCoalescer(policy=FlushPolicy(max_batch_blocks=10 ** 9),
                         capacity=4, **kw)
    for sid in sids:
        co.open_stream(sid)
    outs = {sid: [] for sid in sids}
    feeds = []
    for lo in range(0, data.shape[1], 96):
        for ci, sid in enumerate(sids):
            assert co.submit(sid, data[ci, lo:lo + 96]) is None
        res = co.flush()
        feeds.append((lo, min(lo + 96, data.shape[1])))
        for sid in sids:
            outs[sid].append(res.get(sid, b""))
    n_flush_dispatches = co._mixed.dispatches
    for sid in sids:
        outs[sid].append(co.close_stream(sid))
    # one dispatch per flush that produced blocks, for all streams together
    assert n_flush_dispatches == sum(
        1 for lo, hi in feeds if (hi - lo) >= B) == len(feeds)

    codec = IdealemCodec(**kw)
    for ci, sid in enumerate(sids):
        s = codec.session()
        ref = [s.feed(data[ci, lo:hi]) for lo, hi in feeds] + [s.finish()]
        assert b"".join(ref) == b"".join(outs[sid])
        y = decode_stream(b"".join(outs[sid]))
        assert np.max(np.abs(y - data[ci])) <= 0.6 + 1e-9


def test_adaptive_coalescer_slot_reuse_is_fresh():
    from repro.serve.compress import StreamCoalescer
    from repro.serve.engine import FlushPolicy
    kw = dict(mode="std", block_size=B, num_dict=4, backend="jax",
              adaptive=True, selector=SEL)
    co = StreamCoalescer(policy=FlushPolicy(max_batch_blocks=10 ** 9),
                         capacity=1, **kw)
    x = _signals(1, B * 12, seed=11)[0]
    co.open_stream("a")
    co.submit("a", x)
    first = co.flush()["a"] + co.close_stream("a")
    co.open_stream("b")         # recycles slot 0: must look fresh
    co.submit("b", x)
    second = co.flush()["b"] + co.close_stream("b")
    assert first == second


def test_adaptive_coalescer_rejects_ops_matcher():
    from repro.serve.compress import StreamCoalescer
    with pytest.raises(ValueError, match="masked variant"):
        StreamCoalescer(mode="std", block_size=B, num_dict=8,
                        backend="jax", adaptive=True, matcher="ops")


# ----------------------------------------------------------- hypothesis fuzz
try:
    import hypothesis  # noqa: F401

    from hypothesis import given, settings

    from conftest import switch_schedules

    _N = 40 if os.environ.get("HYPOTHESIS_PROFILE") == "ci" else 5

    @given(switch_schedules())
    @settings(max_examples=_N, deadline=None)
    def test_fuzz_switch_schedules(case):
        kwargs, x, feed = case
        kwargs = dict(kwargs, selector=SEL)
        segs = {}
        for backend in ("numpy", "jax"):
            codec = IdealemCodec(backend=backend, **kwargs)
            s = codec.session(channels=x.shape[0])
            out = [s.feed(x[:, lo:lo + feed])
                   for lo in range(0, x.shape[1], feed)]
            out.append(s.finish())
            segs[backend] = out
        assert segs["numpy"] == segs["jax"]

except ImportError:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fuzz_switch_schedules():
        pass
