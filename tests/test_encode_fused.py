"""Fused single-dispatch encode step (kernels/encode_step.py): decision
parity vs the reference matcher and the composed pallas path, edge sizes,
resumable state, masked padding, tile_d sweeps, the typed kernel-shape
error, and the encode-side measured autotuner (DESIGN.md Sec. 10)."""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.encoder import (encode_decisions, encode_decisions_batched,
                                init_state, matcher_reference)
from repro.kernels.dict_match import TILE_D, KernelShapeError

# TILE_D+-1 straddles the tile boundary; 1 and 255 are the codec's D range
EDGE_D = [1, TILE_D - 1, TILE_D + 1, 255]


def _mixture_blocks(nb, n, dtype=np.float32, seed=0):
    """Hits, misses and FIFO overwrites all occur on this traffic."""
    rng = np.random.default_rng(seed)
    parts = [rng.normal(m, s, size=(nb // 3, n))
             for m, s in [(0, 1), (5, 0.5), (0, 1)]]
    parts.append(rng.normal(0, 1, size=(nb - 3 * (nb // 3), n)))
    return np.concatenate(parts).astype(dtype)


def _assert_same_decisions(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------ decision-parity ring
@pytest.mark.parametrize("num_dict", EDGE_D)
@pytest.mark.parametrize("n", [TILE_D - 1, 24, 256])
def test_fused_matches_reference(num_dict, n):
    # d_crit between KS jump points (multiples of 1/n) so ulp-level
    # arithmetic differences between matchers cannot flip a decision
    d_crit = (int(0.4 * n) + 0.5) / n
    blocks = jnp.asarray(_mixture_blocks(45, n))
    kw = dict(num_dict=num_dict, d_crit=d_crit, rel_tol=0.5)
    ref = encode_decisions(blocks, **kw)
    _assert_same_decisions(ref, encode_decisions(blocks, matcher="fused", **kw))


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_fused_dtype_ring(dtype):
    n = 24
    blocks = jnp.asarray(_mixture_blocks(36, n, dtype=dtype))
    kw = dict(num_dict=7, d_crit=(int(0.4 * n) + 0.5) / n, rel_tol=0.5)
    ref = encode_decisions(blocks, **kw)
    fused = encode_decisions(blocks, matcher="fused", **kw)
    _assert_same_decisions(ref, fused)


def test_fused_matches_ops():
    """Fused and composed pallas paths share the same kernel arithmetic.

    KS values are multiples of 1/n, so any threshold strictly between two
    jump points decides identically (XLA fusion differences -- e.g. FMA
    contraction -- can move a KS value by one ulp, which only matters for
    a d_crit placed exactly on k/n; ``critical_distance`` never is)."""
    n = 24
    blocks = jnp.asarray(_mixture_blocks(60, n, seed=3))
    for k in (6, 8, 10):  # thresholds mid-gap between k/n and (k+1)/n
        kw = dict(num_dict=9, d_crit=(k + 0.5) / n, rel_tol=0.5)
        _assert_same_decisions(
            encode_decisions(blocks, matcher="ops", **kw),
            encode_decisions(blocks, matcher="fused", **kw))


@pytest.mark.parametrize("use_minmax,use_ks", [(False, True), (True, False)])
def test_fused_ablation_parity(use_minmax, use_ks):
    blocks = jnp.asarray(_mixture_blocks(40, 16, seed=5))
    kw = dict(num_dict=7, d_crit=0.4, rel_tol=0.5,
              use_minmax=use_minmax, use_ks=use_ks)
    _assert_same_decisions(encode_decisions(blocks, **kw),
                           encode_decisions(blocks, matcher="fused", **kw))


def test_minmax_gate_boundary():
    """Exactly-on-threshold extremes (eq. 3 is inclusive) decide the same
    through reference, ops and fused -- boundary values chosen exactly
    representable so all paths see the identical comparison."""
    n = 16
    base = np.linspace(0.0, 1.0, n, dtype=np.float32)  # dmin=0, dmax=1
    on = base.copy()
    on[0], on[-1] = -0.5, 1.5       # exactly dmin - t and dmax + t (r=0.5)
    off = base.copy()
    off[0] = np.nextafter(np.float32(-0.5), np.float32(-1.0))  # just outside
    blocks = jnp.asarray(np.stack([base, on, off]))
    kw = dict(num_dict=3, d_crit=2.0, rel_tol=0.5)  # KS always passes
    ref = encode_decisions(blocks, **kw)
    _assert_same_decisions(ref, encode_decisions(blocks, matcher="fused", **kw))
    _assert_same_decisions(ref, encode_decisions(blocks, matcher="ops", **kw))
    hits = np.asarray(ref[0])
    assert hits[1] and not hits[2]  # inclusive on, exclusive just-outside


# ------------------------------------------------- streaming / masked cases
def test_fused_resumable_state():
    blocks = jnp.asarray(_mixture_blocks(90, 24, seed=7))
    kw = dict(num_dict=7, d_crit=0.4, rel_tol=0.5)
    ref = encode_decisions(blocks, **kw)
    state = init_state(7, 24)
    parts = []
    for lo in range(0, 90, 17):
        out, state = encode_decisions(blocks[lo:lo + 17], matcher="fused",
                                      state=state, **kw)
        parts.append(out)
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(ref[i]),
            np.concatenate([np.asarray(p[i]) for p in parts]))
    # the carry itself matches a reference-matcher scan (same dictionary)
    _, ref_state = encode_decisions(blocks, state=init_state(7, 24), **kw)
    np.testing.assert_array_equal(np.asarray(state.valid),
                                  np.asarray(ref_state.valid))
    np.testing.assert_array_equal(np.asarray(state.sorted_blocks),
                                  np.asarray(ref_state.sorted_blocks))
    assert int(state.count) == int(ref_state.count)


def test_fused_masked_padding_is_noop():
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.normal(size=(50, 16)), jnp.float32)
    kw = dict(num_dict=5, d_crit=0.45, rel_tol=0.5)
    ref = encode_decisions(blocks, matcher="fused", **kw)
    blk2 = jnp.zeros((100, 16), jnp.float32).at[::2].set(blocks)
    valid = np.zeros(100, dtype=bool)
    valid[::2] = True
    out = encode_decisions(blk2, matcher="fused", valid=jnp.asarray(valid),
                           **kw)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(ref[i]),
                                      np.asarray(out[i])[::2])
        assert not np.any(np.asarray(out[i])[1::2])


def test_fused_batched_channels():
    rng = np.random.default_rng(1)
    bc = jnp.asarray(rng.normal(size=(3, 40, 16)), jnp.float32)
    kw = dict(num_dict=5, d_crit=0.45, rel_tol=0.5)
    _assert_same_decisions(
        sum((list(t) for t in encode_decisions_batched(bc, **kw)), []),
        sum((list(t) for t in encode_decisions_batched(
            bc, matcher="fused", **kw)), []))


def test_codec_pallas_backend_byte_identity():
    """End-to-end: the pallas backend (fused matcher default) emits byte-
    identical streams to the numpy early-exit reference, per mode."""
    from repro.core import IdealemCodec

    rng = np.random.default_rng(4)
    x = np.concatenate([rng.normal(m, s, size=500)
                        for m, s in [(0, 1), (5, 0.5), (0, 1)]])
    for mode, vr in [("std", None), ("residual", (0.0, 360.0)),
                     ("delta", None)]:
        xs = np.mod(np.abs(x) * 40.0, 360.0) if vr else x
        kw = dict(mode=mode, block_size=16, num_dict=31, alpha=0.05,
                  rel_tol=0.5, value_range=vr)
        blob_np = IdealemCodec(backend="numpy", **kw).encode(xs)
        blob_pl = IdealemCodec(backend="pallas", **kw).encode(xs)
        assert blob_np == blob_pl


# ------------------------------------------------------- tile_d parameter
@pytest.mark.parametrize("tile_d", [4, TILE_D, 64])
def test_fused_tile_d_sweep_identical(tile_d):
    blocks = jnp.asarray(_mixture_blocks(45, 24, seed=9))
    kw = dict(num_dict=13, d_crit=0.4, rel_tol=0.5)
    ref = encode_decisions(blocks, matcher="fused", **kw)
    _assert_same_decisions(
        ref, encode_decisions(blocks, matcher=("fused", tile_d), **kw))


def test_dict_match_tile_d_param():
    from repro.kernels.ops import dict_match

    rng = np.random.default_rng(2)
    xs = jnp.sort(jnp.asarray(rng.normal(size=16), jnp.float32))
    dic = jnp.asarray(rng.normal(size=(10, 16)), jnp.float32)
    dmin, dmax = dic.min(axis=1), dic.max(axis=1)
    ks8, mm8 = dict_match(xs, dic, dmin, dmax, rel_tol=0.5)
    ks4, mm4 = dict_match(xs, dic, dmin, dmax, rel_tol=0.5, tile_d=4)
    np.testing.assert_array_equal(np.asarray(ks8), np.asarray(ks4))
    np.testing.assert_array_equal(np.asarray(mm8), np.asarray(mm4))


def test_kernel_shape_error_is_typed():
    from repro.kernels.dict_match import dict_match_pallas
    from repro.kernels.encode_step import encode_step_pallas

    rng = np.random.default_rng(0)
    xs = jnp.sort(jnp.asarray(rng.normal(size=8), jnp.float32))
    dic = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    mn = mx = jnp.zeros(5, jnp.float32)
    with pytest.raises(KernelShapeError) as ei:
        dict_match_pallas(xs, dic, mn, mx, 0.5)
    assert "D=5" in str(ei.value) and "3 more row" in str(ei.value)
    assert isinstance(ei.value, ValueError)  # typed, but still a ValueError
    with pytest.raises(KernelShapeError):
        encode_step_pallas(xs, dic, mn, mx, jnp.zeros(5, bool),
                           jnp.int32(0), jnp.asarray(True), d_crit=0.4,
                           rel_tol=0.5, num_dict=5)


# ------------------------------------------------------ measured autotuner
def test_encode_autotune_lifecycle(tmp_path, monkeypatch):
    from repro.core import encoder as enc
    from repro.core.tuning import AutotuneCacheError

    path = str(tmp_path / "encode_autotune.json")
    monkeypatch.setenv("REPRO_ENCODE_AUTOTUNE", path)
    enc.reset_encode_autotune()
    blocks = jnp.asarray(_mixture_blocks(12, 16, seed=1))
    kw = dict(num_dict=5, d_crit=0.4, rel_tol=0.5)
    assert not enc.encode_autotune_cached(5, 16, np.float32)
    ref = encode_decisions(blocks, **kw)
    out = encode_decisions(blocks, matcher="auto", **kw)
    _assert_same_decisions(ref, out)  # whatever won, decisions agree
    assert enc.encode_autotune_cached(5, 16, np.float32)
    assert os.path.exists(path)
    doc = json.load(open(path))
    assert doc["version"] == enc.ENCODE_AUTOTUNE_VERSION
    (key, ent), = doc["entries"].items()
    assert ent["matcher"] in enc.MATCHERS and "times_us" in ent

    # persisted choice survives a reset + reload; a second resolve is a hit
    enc.reset_encode_autotune()
    assert enc.load_encode_autotune(path) == 1
    assert enc.encode_autotune_choices()[key] == ent["matcher"]
    probes_before = enc._TUNER.stats["probes"]
    encode_decisions(blocks, matcher="auto", **kw)
    assert enc._TUNER.stats["probes"] == probes_before  # served from cache

    # stale version: strict load raises, non-strict discards and re-probes
    doc["version"] = 999
    with open(path, "w") as f:
        json.dump(doc, f)
    enc.reset_encode_autotune()
    with pytest.raises(AutotuneCacheError):
        enc.load_encode_autotune(path)
    enc.reset_encode_autotune()
    assert enc.load_encode_autotune(path, strict=False) == 0
    enc.reset_encode_autotune()


def test_unknown_matcher_rejected():
    blocks = jnp.asarray(_mixture_blocks(6, 16))
    with pytest.raises(ValueError, match="unknown matcher"):
        encode_decisions(blocks, num_dict=3, d_crit=0.4, matcher="warp")
    from repro.core import IdealemCodec
    with pytest.raises(ValueError, match="matcher"):
        IdealemCodec(matcher="warp")
