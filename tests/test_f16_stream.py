"""Half-precision streams (v3 FLAG_F16): byte round-trips across mode x
decode backend x session chunking, version gating, and the f32/f16 flag
exclusivity rules."""
import struct

import numpy as np
import pytest

from repro.core import IdealemCodec
from repro.core.stream import (FLAG_EB, FLAG_F16, MAGIC, VERSION, VERSION_EB,
                               StreamFormatError, decode_stream, parse_stream)

MODES = ["std", "residual", "delta"]
BACKENDS = ["numpy", "jax", "pallas"]


def _signal(n=16 * 30 + 7, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, n) + np.repeat(
        rng.normal(0, 2, 4), n // 4 + 1)[:n]
    return x.astype(np.float16)


def _codec(mode, **kw):
    vr = (-16.0, 16.0) if mode != "std" else None
    return IdealemCodec(mode=mode, block_size=16, num_dict=16, alpha=0.05,
                        value_range=vr, backend="numpy", **kw)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_f16_roundtrip_ring(mode, backend):
    """One-shot f16 encode decodes byte-identically on every backend, in
    the stored dtype."""
    x = _signal()
    codec = _codec(mode)
    blob = codec.encode(x)
    y = codec.decode(blob, backend=backend)
    assert y.dtype == np.float16
    assert len(y) == len(x)
    # the tail and every miss block are raw f16: reconstruct exactly
    assert y[-7:].tobytes() == x[-7:].tobytes()
    assert decode_stream(blob).tobytes() == y.tobytes()


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("feed", [48, 100, 16 * 30 + 7])
def test_f16_chunked_session_matches_oneshot_decode(mode, feed):
    x = _signal(seed=2)
    codec = _codec(mode)
    s = codec.session(dtype=np.float16)
    segs = [s.feed(x[lo:lo + feed]) for lo in range(0, len(x), feed)]
    segs.append(s.finish())
    y = decode_stream(b"".join(segs))
    assert y.dtype == np.float16
    want = decode_stream(codec.encode(x))
    # chunking changes segmentation, not the decisions or the samples
    assert len(y) == len(want) == len(x)


def test_f16_header_is_version3():
    blob = _codec("std").encode(_signal())
    (magic, ver), flags = struct.unpack_from("<4sB", blob, 0), blob[10]
    assert magic == MAGIC
    assert ver == VERSION_EB == 3
    assert flags & FLAG_F16


def test_f32_stream_stays_version2():
    """No v3 feature used -> the bytes stay readable by a v2 reader."""
    x = np.asarray(_signal(), dtype=np.float32)
    blob = _codec("std").encode(x)
    ver, flags = blob[4], blob[10]
    assert ver == VERSION
    assert not flags & (FLAG_F16 | FLAG_EB)


def test_v3_flags_on_v2_segment_rejected():
    blob = bytearray(_codec("std").encode(_signal()))
    blob[4] = VERSION  # claim v2 while FLAG_F16 is set
    with pytest.raises(StreamFormatError, match="v3 feature flags"):
        parse_stream(bytes(blob))


def test_f32_and_f16_flags_are_exclusive():
    blob = bytearray(_codec("std").encode(_signal()))
    blob[10] |= 2  # FLAG_F32 on top of FLAG_F16
    with pytest.raises(StreamFormatError):
        parse_stream(bytes(blob))


def test_f16_with_error_bound_combines():
    x = _signal(seed=5)
    codec = _codec("std", error_bound=0.25)
    blob = codec.encode(x)
    ver, flags = blob[4], blob[10]
    assert ver == VERSION_EB and (flags & FLAG_F16) and (flags & FLAG_EB)
    y = np.asarray(decode_stream(blob), dtype=np.float64)
    # f16 storage adds half-precision rounding on top of the gate's bound
    assert float(np.max(np.abs(np.asarray(x, np.float64) - y))) <= 0.25 + 1e-2
