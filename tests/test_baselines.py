"""Error-bound and roundtrip checks for the comparison codecs."""
import numpy as np
import pytest

# the entropy stage falls back to stdlib zlib when zstandard is absent
# (repro.baselines._entropy), so these run everywhere
from repro.baselines import IsabelaLikeCodec, SzLikeCodec, ZfpLikeCodec


def _signal(n=20_000, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    return np.sin(t * 0.01) * 10 + rng.normal(0, 0.05, n)


@pytest.mark.parametrize("n", [0, 1, 5, 4096, 20_000])
def test_zfp_like_roundtrip_and_bound(n):
    x = _signal(max(n, 1))[:n]
    c = ZfpLikeCodec(tolerance=1e-2)
    y = c.decode(c.encode(x))
    assert len(y) == n
    if n:
        assert np.max(np.abs(x - y)) < 4 * c.tolerance  # transform constant


def test_sz_like_roundtrip_and_bound():
    x = _signal()
    c = SzLikeCodec(rel_bound_ratio=1e-3)
    y = c.decode(c.encode(x))
    rng = x.max() - x.min()
    assert len(y) == len(x)
    assert np.max(np.abs(x - y)) <= 1e-3 * rng * (1 + 1e-9)


def test_isabela_like_roundtrip_and_relative_bound():
    x = _signal()
    c = IsabelaLikeCodec(window=512, num_coeff=15, error_rate=5.0)
    y = c.decode(c.encode(x))
    assert len(y) == len(x)
    scale = np.maximum(np.abs(x), 1e-30)
    # per-point relative bound honored (corrections patch violations)
    assert np.max(np.abs(x - y) / scale) <= 0.05 + 1e-9


def test_ratios_compress_at_all():
    x = _signal()
    for c in [ZfpLikeCodec(1e-2), SzLikeCodec(1e-3), IsabelaLikeCodec()]:
        blob = c.encode(x)
        assert x.nbytes / len(blob) > 1.5
