# Convenience targets; all run from the repo root.
PY ?= python
export PYTHONPATH := src

.PHONY: test test-core bench bench-quick bench-gate bench-stream \
	bench-shard bench-store bench-decode bench-encode bench-adaptive \
	bench-frontier \
	bench-obs bench-frontend shard-check store-check store-check-quick \
	obs-check serve-check serve-soak lint example-stream example-serve

# Tier-1 verification (ROADMAP.md): the full suite, fail-fast.
test:
	$(PY) -m pytest -x -q

# Fast loop while working on the codec core.
test-core:
	$(PY) -m pytest -q tests/test_core_codec.py tests/test_core_ks.py \
	    tests/test_kernels.py tests/test_stream_format.py \
	    tests/test_streaming_session.py

bench:
	$(PY) -m benchmarks.run

bench-stream:
	$(PY) -m benchmarks.bench_stream_io

bench-shard:
	$(PY) -m benchmarks.bench_shard_encode

bench-store:
	$(PY) -m benchmarks.bench_store_decode

# Host vs device reconstruct through the unified decode engine.
bench-decode:
	$(PY) -m benchmarks.bench_decode_backends

# Fused single-dispatch encode step vs the composed matcher pipeline
# (fails below the 1.3x acceptance bar).
bench-encode:
	$(PY) -m benchmarks.bench_encode_fused

# Batched mixed-mode adaptive encode vs the per-channel loop (fails
# below the 2x acceptance bar at C=64).
bench-adaptive:
	$(PY) -m benchmarks.bench_adaptive_batch

# Rate-distortion frontier: error-bounded IDEALEM vs the baseline codecs.
bench-frontier:
	$(PY) -m benchmarks.bench_frontier

# Telemetry overhead: metrics-on vs metrics-off encode/decode, fails
# when the modeled obs cost exceeds the 3% bar (DESIGN.md Sec. 12).
bench-obs:
	$(PY) -m benchmarks.bench_obs_overhead

# CI smoke profile: small workloads, fast host/codec benches only.
bench-quick:
	$(PY) -m benchmarks.run --quick

# The PR perf gate, exactly as CI runs it: quick profile -> JSON ->
# compare against the committed CPU baseline (scripts/bench_gate.py).
bench-gate:
	$(PY) -m benchmarks.run --quick --json BENCH_quick.json
	$(PY) scripts/bench_gate.py BENCH_quick.json \
	    benchmarks/baselines/BENCH_quick.json

# Telemetry self-check (CI tier1): exporter round trip on a scratch
# registry, then a live end-to-end workload that must populate the
# expected metric families across encode/decode/store/serve from one
# registry snapshot (scripts/obs_tool.py).
obs-check:
	$(PY) scripts/obs_tool.py selfcheck

# Serving front end smoke (CI tier1): the closed-loop load generator at
# the CI profile -- >= 8 concurrent verified tenants plus a rate-limited
# one over the real wire protocol, zero byte-diffs vs direct sessions,
# typed rejections visible in /metrics, p99 SLOs asserted from the
# scraped exposition (scripts/loadgen.py).
serve-check:
	$(PY) scripts/loadgen.py --smoke --json LOADGEN_smoke.json

# Nightly soak profile: longer traces, same checks, artifact uploaded.
serve-soak:
	$(PY) scripts/loadgen.py --tenants 12 --samples 32768 \
	    --json LOADGEN_soak.json

# Wire-level serving throughput rows (full bench profile only).
bench-frontend:
	$(PY) -m benchmarks.bench_frontend

lint:
	ruff check .

# Sharded-encode byte-identity self-check on forced host devices.
shard-check:
	REPRO_SHARD_DEVICES=4 $(PY) -m repro.launch.shard_check

# Container range-decode == sequential-decode-slice over the golden corpus
# (also through a mmap-backed file open), plus a size-capped synthetic
# >RAM-budget archive verified via Container.open(mmap=True).
store-check:
	$(PY) scripts/store_tool.py selfcheck tests/golden/*.idlm
	$(PY) scripts/store_tool.py selfcheck tests/golden/*.idlm --mmap
	$(PY) scripts/store_tool.py bigcheck --mb 48 --mmap

# PR-level smoke (CI tier1): the golden-corpus selfcheck plus a small
# mmap bigcheck, so container-format regressions fail fast instead of
# waiting for the nightly store-check.
store-check-quick:
	$(PY) scripts/store_tool.py selfcheck tests/golden/*.idlm
	$(PY) scripts/store_tool.py bigcheck --mb 8 --mmap

example-stream:
	$(PY) examples/stream_compress.py --channels 8 --samples 16384

example-serve:
	$(PY) examples/serve_frontend.py --tenants 4
