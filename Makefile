# Convenience targets; all run from the repo root.
PY ?= python
export PYTHONPATH := src

.PHONY: test test-core bench bench-stream bench-shard shard-check \
	example-stream

# Tier-1 verification (ROADMAP.md): the full suite, fail-fast.
test:
	$(PY) -m pytest -x -q

# Fast loop while working on the codec core.
test-core:
	$(PY) -m pytest -q tests/test_core_codec.py tests/test_core_ks.py \
	    tests/test_kernels.py tests/test_stream_format.py \
	    tests/test_streaming_session.py

bench:
	$(PY) -m benchmarks.run

bench-stream:
	$(PY) -m benchmarks.bench_stream_io

bench-shard:
	$(PY) -m benchmarks.bench_shard_encode

# Sharded-encode byte-identity self-check on forced host devices.
shard-check:
	REPRO_SHARD_DEVICES=4 $(PY) -m repro.launch.shard_check

example-stream:
	$(PY) examples/stream_compress.py --channels 8 --samples 16384
